"""MIFA server-aggregation throughput: fused kernel, then sharded meshes.

Two sections:

**Single device** (the original benchmark): the aggregation is memory-bound;
the fused Pallas kernel halves HBM traffic (docs/architecture.md §4). On this
CPU container we time the *jnp reference* and the *fused-traffic jnp
equivalent* (single-pass) and report the derived bytes moved; the Pallas
kernel itself runs in interpret mode (correctness-only).

**Sharded meshes** (docs/architecture.md §13): the same fused aggregation
compiled under explicit shardings on forced-host-device meshes — G and U
with clients on ``data`` and features on ``model``, w on ``model`` — run in
a subprocess with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
(the tests/conftest.py mesh-world mechanism; XLA only honours the flag
before the backend initialises, so the parent benchmark process cannot host
the mesh itself). Per mesh shape we record wall time, derived aggregate
throughput, and the roofline validation: `roofline.analysis.analyze_compiled`
parses the per-device SPMD HLO and its HBM-traffic estimate must land within
a sanity band of the analytic per-device byte count (`ROOFLINE_BAND`) —
pinned by the CI gate together with the deterministic output checksum
(benchmarks/baselines/ci_baseline.json). Multi-device shapes must also show
nonzero collective traffic (the client-axis mean all-reduces partial sums);
their checksums agree with 1×1 to fp32 reduction-order tolerance only, so
the artifact records the cross-mesh `checksum_spread` instead of pinning
bit-equality.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
from common import ARTIFACTS, emit, save_artifact, timeit_us

from repro.kernels.mifa_aggregate import mifa_aggregate
from repro.kernels.ref import mifa_aggregate_ref

# analytic-vs-parsed HBM traffic sanity band (per device): the HLO parser
# charges operand+result bytes per materialized op, so fusion decisions move
# the estimate around the analytic single-pass count but must stay this side
# of an order of magnitude
ROOFLINE_BAND = (0.2, 5.0)

MESH_SHAPES = ((1, 1), (8, 1), (2, 4))

MESH_ENV = {
    "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
    "JAX_PLATFORMS": "cpu",
    "JAX_THREEFRY_PARTITIONABLE": "1",
}


def naive(g_old, u, active, w, eta):
    """Unfused composition: select -> write G -> re-read G -> mean -> update."""
    act = active.reshape(-1, 1)
    g_new = jnp.where(act, u.astype(g_old.dtype), g_old)
    mean_g = jnp.mean(g_new.astype(jnp.float32), axis=0)
    w_new = (w.astype(jnp.float32) - eta * mean_g).astype(w.dtype)
    return g_new, w_new


def fused(g_old, u, active, w, eta):
    """Single-pass f32 aggregation — the body the sharded section compiles."""
    act = active.reshape(-1, 1)
    g_new = jnp.where(act, u, g_old)
    w_new = w - eta * jnp.mean(g_new, axis=0)
    return g_new, w_new


# --------------------------------------------------------------------------- #
# sharded section (runs inside the forced-8-device subprocess)
# --------------------------------------------------------------------------- #

def _shard_one(n: int, m: int, shape: tuple[int, int]) -> dict:
    """Time + roofline-validate the fused aggregation on one mesh shape."""
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import make_host_mesh
    from repro.roofline.analysis import analyze_compiled, roofline_terms
    from repro.sharding.rules import sanitize

    mesh = make_host_mesh(*shape)
    put = lambda x, *spec: jax.device_put(
        x, NamedSharding(mesh, P(*sanitize(spec, x.shape, mesh))))

    rng = jax.random.PRNGKey(0)
    g = put(jax.random.normal(rng, (n, m), jnp.float32), "data", "model")
    u = put(jax.random.normal(jax.random.fold_in(rng, 1), (n, m),
                              jnp.float32), "data", "model")
    active = put(jax.random.bernoulli(jax.random.fold_in(rng, 2), 0.5, (n,)),
                 "data")
    w = put(jnp.zeros((m,), jnp.float32), "model")
    eta = jnp.float32(0.1)

    fn = jax.jit(fused)
    compiled = fn.lower(g, u, active, w, eta).compile()
    us = timeit_us(fn, g, u, active, w, eta, iters=5)
    _, w_new = fn(g, u, active, w, eta)
    checksum = float(jnp.sum(w_new))

    n_dev = shape[0] * shape[1]
    # single-pass f32 traffic: read G + read U + write G + read/write w-size
    analytic_total = 3 * n * m * 4 + 2 * m * 4
    analytic_per_dev = analytic_total / n_dev
    analysis = analyze_compiled(compiled)
    parsed = analysis["hlo_bytes_parsed"]
    ratio = parsed / analytic_per_dev
    return {
        "mesh": f"{shape[0]}x{shape[1]}", "n_devices": n_dev,
        "us": us, "gbps": analytic_total / us / 1e3,
        "checksum": checksum,
        "analytic_bytes_per_dev": analytic_per_dev,
        "parsed_bytes_per_dev": parsed,
        "roofline_traffic_ratio": ratio,
        "collective_bytes_total": analysis["collective_bytes_total"],
        "roofline_terms": roofline_terms(analysis),
    }


def sharded_worker(out_path: str, fast: bool) -> None:
    """Subprocess entry: 8 forced host devices, writes the section JSON."""
    assert len(jax.devices()) >= 8, (
        "sharded worker needs the forced-8-device world; run via main()")
    sizes = [(16, 1 << 16)] if fast else [(16, 1 << 16), (16, 1 << 20),
                                          (64, 1 << 18)]
    out = []
    for n, m in sizes:
        shapes = {}
        for shape in MESH_SHAPES:
            r = _shard_one(n, m, shape)
            shapes[r["mesh"]] = r
        ref = shapes["1x1"]["checksum"]
        entry = {
            "n": n, "m": m, "shapes": shapes,
            "checksum_1x1": ref,
            # >1-device means differ by fp32 reduction grouping only
            "checksum_spread": max(abs(s["checksum"] - ref)
                                   for s in shapes.values()),
        }
        lo, hi = ROOFLINE_BAND
        for s in shapes.values():
            assert lo < s["roofline_traffic_ratio"] < hi, (
                f"roofline traffic estimate off the sanity band: {s}")
            if s["n_devices"] > 1:
                assert s["collective_bytes_total"] > 0, (
                    f"sharded program shows no collectives: {s}")
        out.append(entry)
    with open(out_path, "w") as f:
        json.dump(out, f, default=float)


def run_sharded_section(fast: bool) -> list:
    """Spawn the forced-8-device worker and collect its results."""
    with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as tmp:
        out_path = tmp.name
    try:
        cmd = [sys.executable, os.path.abspath(__file__),
               "--sharded-worker", out_path]
        if fast:
            cmd.append("--fast")
        proc = subprocess.run(
            cmd, env={**os.environ, **MESH_ENV},
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=1800)
        if proc.returncode != 0:
            raise RuntimeError(
                f"sharded agg worker failed:\n{proc.stdout[-2000:]}"
                f"\n{proc.stderr[-4000:]}")
        with open(out_path) as f:
            return json.load(f)
    finally:
        os.unlink(out_path)


# --------------------------------------------------------------------------- #

def main(fast: bool = False) -> None:
    rows = []
    sizes = [(16, 1 << 20), (16, 1 << 22)] if fast else \
        [(16, 1 << 20), (16, 1 << 22), (64, 1 << 22), (100, 1 << 20)]
    for n, m in sizes:
        rng = jax.random.PRNGKey(0)
        g = jax.random.normal(rng, (n, m), jnp.float32).astype(jnp.bfloat16)
        u = jax.random.normal(jax.random.fold_in(rng, 1), (n, m), jnp.float32)
        active = jax.random.bernoulli(jax.random.fold_in(rng, 2), 0.5, (n,))
        w = jnp.zeros((m,), jnp.bfloat16)
        eta = jnp.float32(0.1)

        us_naive = timeit_us(jax.jit(naive), g, u, active, w, eta, iters=5)
        # bytes: naive = read G + read U + write G + read G + write w
        naive_bytes = (3 * n * m * 2) + (n * m * 4) + m * 2
        fused_bytes = (2 * n * m * 2) + (n * m * 4) + m * 2
        rows.append({"n": n, "m": m, "us_naive": us_naive,
                     "naive_bytes": naive_bytes, "fused_bytes": fused_bytes,
                     "traffic_ratio": naive_bytes / fused_bytes})
        emit(f"agg_throughput/n{n}_m{m}", us_naive,
             f"traffic_ratio={naive_bytes / fused_bytes:.3f}")

    # kernel correctness spot check (interpret mode)
    n, m = 8, 4096
    g = jnp.zeros((n, m), jnp.float32)
    u = jnp.ones((n, m), jnp.float32)
    act = jnp.ones((n,), bool)
    w = jnp.zeros((m,), jnp.float32)
    gk, wk = mifa_aggregate(g, u, act, w, 0.5, block_m=512)
    gr, wr = mifa_aggregate_ref(g, u, act, w, 0.5)
    ok = bool(jnp.allclose(gk, gr) and jnp.allclose(wk, wr))
    emit("agg_throughput/kernel_allclose", 0.0, ok)

    sharded = run_sharded_section(fast)
    for entry in sharded:
        for mesh_key, s in entry["shapes"].items():
            emit(f"agg_throughput/sharded_n{entry['n']}_m{entry['m']}"
                 f"_{mesh_key}", s["us"],
                 f"gbps={s['gbps']:.2f};"
                 f"roofline_ratio={s['roofline_traffic_ratio']:.2f};"
                 f"coll_bytes={s['collective_bytes_total']:.0f}")

    save_artifact("agg_throughput",
                  {"rows": rows, "kernel_ok": ok, "sharded": sharded})
    if not fast:
        write_md({"rows": rows, "sharded": sharded})
    assert ok


def write_md(payload: dict) -> None:
    lines = [
        "# MIFA aggregation throughput: fused traffic + sharded meshes",
        "",
        "Single-device section: unfused composition timed on CPU with the "
        "derived HBM byte counts — the fused single-pass form moves "
        f"{payload['rows'][0]['traffic_ratio']:.2f}x less traffic "
        "(docs/architecture.md §4). Sharded section: the fused f32 "
        "aggregation compiled under explicit shardings (clients on `data`, "
        "features on `model`) on forced-host-device meshes "
        "(docs/architecture.md §13); `roofline ratio` is the per-device "
        "HLO-parsed traffic estimate over the analytic single-pass bytes "
        f"and must sit inside {ROOFLINE_BAND}. Throughput is derived "
        "(analytic bytes / wall time) on CPU — the mesh worlds exist for "
        "layout/parity correctness, not absolute speed. "
        "`benchmarks/agg_throughput.py` regenerates this file.",
        "",
        "| n | m | mesh | time (us) | derived GB/s | roofline ratio | "
        "collective bytes/dev | checksum |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for entry in payload["sharded"]:
        for s in entry["shapes"].values():
            lines.append(
                f"| {entry['n']} | {entry['m']} | {s['mesh']} | "
                f"{s['us']:.0f} | {s['gbps']:.2f} | "
                f"{s['roofline_traffic_ratio']:.2f} | "
                f"{s['collective_bytes_total']:.0f} | "
                f"{s['checksum']:.6g} |")
        lines.append(
            f"| {entry['n']} | {entry['m']} | *spread* |  |  |  |  | "
            f"{entry['checksum_spread']:.3g} |")
    lines += [
        "",
        "Multi-device checksums agree with 1x1 to fp32 reduction-order "
        "tolerance (the client-axis mean all-reduces per-device partial "
        "sums — same math, different parenthesisation); `spread` rows "
        "record the observed max deviation. The CI gate pins the 1x1 "
        "checksum and the roofline band for the fast size "
        "(benchmarks/baselines/ci_baseline.json).",
        "",
    ]
    path = os.path.join(ARTIFACTS, "agg_throughput.md")
    os.makedirs(ARTIFACTS, exist_ok=True)
    with open(path, "w") as f:
        f.write("\n".join(lines))
    print(f"wrote {path}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--sharded-worker", default=None, metavar="OUT_JSON")
    args = ap.parse_args()
    if args.sharded_worker:
        sharded_worker(args.sharded_worker, args.fast)
    else:
        main(fast=args.fast)
