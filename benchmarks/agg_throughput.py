"""MIFA server-aggregation throughput: fused kernel vs naive composition.

The aggregation is memory-bound; the fused Pallas kernel halves HBM traffic
(docs/architecture.md kernels). On this CPU container we time the *jnp reference* and the
*fused-traffic jnp equivalent* (single-pass) and report the derived bytes
moved; the Pallas kernel itself runs in interpret mode (correctness-only).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from common import emit, save_artifact, timeit_us

from repro.kernels.mifa_aggregate import mifa_aggregate
from repro.kernels.ref import mifa_aggregate_ref


def naive(g_old, u, active, w, eta):
    """Unfused composition: select -> write G -> re-read G -> mean -> update."""
    act = active.reshape(-1, 1)
    g_new = jnp.where(act, u.astype(g_old.dtype), g_old)
    mean_g = jnp.mean(g_new.astype(jnp.float32), axis=0)
    w_new = (w.astype(jnp.float32) - eta * mean_g).astype(w.dtype)
    return g_new, w_new


def main(fast: bool = False) -> None:
    rows = []
    sizes = [(16, 1 << 20), (16, 1 << 22)] if fast else \
        [(16, 1 << 20), (16, 1 << 22), (64, 1 << 22), (100, 1 << 20)]
    for n, m in sizes:
        rng = jax.random.PRNGKey(0)
        g = jax.random.normal(rng, (n, m), jnp.float32).astype(jnp.bfloat16)
        u = jax.random.normal(jax.random.fold_in(rng, 1), (n, m), jnp.float32)
        active = jax.random.bernoulli(jax.random.fold_in(rng, 2), 0.5, (n,))
        w = jnp.zeros((m,), jnp.bfloat16)
        eta = jnp.float32(0.1)

        us_naive = timeit_us(jax.jit(naive), g, u, active, w, eta, iters=5)
        # bytes: naive = read G + read U + write G + read G + write w
        naive_bytes = (3 * n * m * 2) + (n * m * 4) + m * 2
        fused_bytes = (2 * n * m * 2) + (n * m * 4) + m * 2
        rows.append({"n": n, "m": m, "us_naive": us_naive,
                     "naive_bytes": naive_bytes, "fused_bytes": fused_bytes,
                     "traffic_ratio": naive_bytes / fused_bytes})
        emit(f"agg_throughput/n{n}_m{m}", us_naive,
             f"traffic_ratio={naive_bytes / fused_bytes:.3f}")

    # kernel correctness spot check (interpret mode)
    n, m = 8, 4096
    g = jnp.zeros((n, m), jnp.float32)
    u = jnp.ones((n, m), jnp.float32)
    act = jnp.ones((n,), bool)
    w = jnp.zeros((m,), jnp.float32)
    gk, wk = mifa_aggregate(g, u, act, w, 0.5, block_m=512)
    gr, wr = mifa_aggregate_ref(g, u, act, w, 0.5)
    ok = bool(jnp.allclose(gk, gr) and jnp.allclose(wk, wr))
    emit("agg_throughput/kernel_allclose", 0.0, ok)
    save_artifact("agg_throughput", {"rows": rows, "kernel_ok": ok})
    assert ok


if __name__ == "__main__":
    main()
